"""Deterministic token data pipeline: synthetic LM stream + memmap corpus.

Production shape: an indexable shard-aware source + a host-side prefetch
queue.  Every batch is reproducible from (seed, step) alone, which is what
makes checkpoint/restart and elastic re-sharding exact: a restarted (and
possibly re-sized) job replays the identical global batch sequence.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    corpus_path: str | None = None   # memmap'd uint16/uint32 token file


class TokenSource:
    """Deterministic (seed, step) -> global batch of (tokens, labels)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._corpus = None
        if cfg.corpus_path:
            self._corpus = np.memmap(cfg.corpus_path, dtype=np.uint16, mode="r")

    def global_batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        if self._corpus is not None:
            n = len(self._corpus) - cfg.seq_len - 1
            starts = rng.integers(0, n, size=cfg.global_batch)
            toks = np.stack([self._corpus[s: s + cfg.seq_len + 1] for s in starts])
            toks = toks.astype(np.int32)
        else:
            # synthetic: markov-ish stream so the loss is learnable
            base = rng.integers(0, cfg.vocab_size,
                                size=(cfg.global_batch, cfg.seq_len + 1))
            drift = np.cumsum(rng.integers(0, 3, size=base.shape), axis=1)
            toks = ((base + drift) % cfg.vocab_size).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def host_batch(self, step: int, shard: int, num_shards: int) -> dict[str, np.ndarray]:
        """This host's slice of the global batch (data-parallel sharding)."""
        g = self.global_batch(step)
        per = self.cfg.global_batch // num_shards
        sl = slice(shard * per, (shard + 1) * per)
        return {k: v[sl] for k, v in g.items()}


class Prefetcher:
    """Background-thread prefetch queue over a TokenSource."""

    def __init__(self, source: TokenSource, start_step: int = 0,
                 shard: int = 0, num_shards: int = 1, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._shard = shard
        self._num_shards = num_shards
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.host_batch(step, self._shard, self._num_shards)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[tuple[int, dict[str, np.ndarray]]]:
        while True:
            yield self.q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
