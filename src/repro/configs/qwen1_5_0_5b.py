"""Assigned architecture config (see assignment sheet for source)."""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, SSMConfig  # noqa: F401

CONFIG = ModelConfig(
    name="qwen1.5-0.5b", family="dense",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=2816, vocab_size=151936, qkv_bias=True, tie_embeddings=True,
)

QWEN1_5_0_5B = CONFIG
