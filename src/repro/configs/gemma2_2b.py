"""Assigned architecture config (see assignment sheet for source)."""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, SSMConfig  # noqa: F401

CONFIG = ModelConfig(
    name="gemma2-2b", family="dense",
    num_layers=26, d_model=2304, num_heads=8, num_kv_heads=4,
    d_ff=9216, vocab_size=256000, head_dim=256,
    mlp_kind="geglu", attn_softcap=50.0, logit_softcap=30.0,
    local_window=4096, local_global_period=2,
    post_block_norms=True, embed_scale=True, tie_embeddings=True,
)

GEMMA2_2B = CONFIG
