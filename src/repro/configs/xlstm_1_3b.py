"""Assigned architecture config (see assignment sheet for source)."""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, SSMConfig  # noqa: F401

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    ssm=SSMConfig(d_state=0, expand=2, head_dim=512, chunk=64,
                  slstm_every=8, proj_factor=2.0),
)

XLSTM_1_3B = CONFIG
