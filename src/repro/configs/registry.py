"""Architecture registry: the ten assigned archs + the paper's GPT M1..M4.

Canonical definitions live in one ``configs/<id>.py`` file per architecture.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.configs.dbrx_132b import CONFIG as DBRX_132B
from repro.configs.deepseek_v3_671b import CONFIG as DEEPSEEK_V3_671B
from repro.configs.gemma2_2b import CONFIG as GEMMA2_2B
from repro.configs.llama3_8b import CONFIG as LLAMA3_8B
from repro.configs.musicgen_medium import CONFIG as MUSICGEN_MEDIUM
from repro.configs.qwen1_5_0_5b import CONFIG as QWEN1_5_0_5B
from repro.configs.qwen2_vl_7b import CONFIG as QWEN2_VL_7B
from repro.configs.qwen3_8b import CONFIG as QWEN3_8B
from repro.configs.xlstm_1_3b import CONFIG as XLSTM_1_3B
from repro.configs.zamba2_7b import CONFIG as ZAMBA2_7B


def gpt_paper_model(hidden: int, heads: int, layers: int = 4) -> ModelConfig:
    """Paper Table 2 evaluation models (GPT layers, fp16->bf16)."""
    return ModelConfig(
        name=f"gpt-h{hidden}", family="dense",
        num_layers=layers, d_model=hidden, num_heads=heads, num_kv_heads=heads,
        d_ff=4 * hidden, vocab_size=51200, mlp_kind="gelu",
        norm_kind="layernorm", use_rope=False,
    )


GPT_M1 = gpt_paper_model(2048, 16)
GPT_M2 = gpt_paper_model(4096, 32)
GPT_M3 = gpt_paper_model(8192, 64)
GPT_M4 = gpt_paper_model(12288, 96)

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in (
        DEEPSEEK_V3_671B, DBRX_132B, LLAMA3_8B, QWEN1_5_0_5B, QWEN3_8B,
        GEMMA2_2B, MUSICGEN_MEDIUM, QWEN2_VL_7B, ZAMBA2_7B, XLSTM_1_3B,
    )
}

PAPER_MODELS = {"gpt-m1": GPT_M1, "gpt-m2": GPT_M2, "gpt-m3": GPT_M3, "gpt-m4": GPT_M4}


def get_config(name: str) -> ModelConfig:
    if name in ARCHS:
        return ARCHS[name]
    if name in PAPER_MODELS:
        return PAPER_MODELS[name]
    raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS) + sorted(PAPER_MODELS)}")
