"""Assigned architecture config (see assignment sheet for source)."""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, SSMConfig  # noqa: F401

CONFIG = ModelConfig(
    name="llama3-8b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=128256, rope_theta=500000.0,
)

LLAMA3_8B = CONFIG
