"""Assigned architecture config (see assignment sheet for source)."""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, SSMConfig  # noqa: F401

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
    d_ff=18432,                   # dense layers (first 3); experts use 2048
    vocab_size=129280, head_dim=192,  # qk_nope(128)+qk_rope(64) for MLA
    rope_theta=10000.0,
    moe=MoEConfig(num_experts=256, top_k=8, d_ff_expert=2048,
                  num_shared=1, first_dense_layers=3),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    mtp=True,
)

DEEPSEEK_V3_671B = CONFIG
