"""Assigned architecture config (see assignment sheet for source)."""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, SSMConfig  # noqa: F401

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab_size=152064, rope_theta=1000000.0,
    mrope_sections=(16, 24, 24), frontend="vision_patches",
)

QWEN2_VL_7B = CONFIG
