"""Model / run configuration system.

One frozen dataclass describes every architecture in the zoo; families are
expressed through optional sub-configs (MoE, MLA, SSM) and a block pattern.
"""
from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0          # deepseek shared experts
    capacity_factor: float = 1.0
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.001
    first_dense_layers: int = 0  # deepseek: first k layers are dense


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    expand: int = 2
    head_dim: int = 64
    conv_kernel: int = 4
    chunk: int = 64              # chunked-scan block size
    # zamba2 hybrid: apply the shared attention block every k-th layer
    shared_attn_every: int = 0
    # xlstm: one sLSTM per `slstm_every` blocks (rest mLSTM)
    slstm_every: int = 0
    proj_factor: float = 2.0     # mLSTM up-projection factor


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "hybrid", "ssm", "audio", "vlm"]
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads
    max_seq_len: int = 131072

    # attention variants
    qkv_bias: bool = False
    qk_norm: bool = False
    attn_softcap: float = 0.0          # gemma2: 50.0
    logit_softcap: float = 0.0         # gemma2: 30.0
    local_window: int = 0              # sliding-window size
    local_global_period: int = 0       # gemma2: 2 (alternating local/global)
    rope_theta: float = 10000.0
    use_rope: bool = True
    mrope_sections: tuple[int, ...] = ()  # qwen2-vl M-RoPE
    post_block_norms: bool = False     # gemma2 post-attn/post-ffn RMSNorm
    embed_scale: bool = False          # gemma2: x *= sqrt(d_model)

    mlp_kind: Literal["swiglu", "geglu", "gelu"] = "swiglu"
    norm_kind: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    mtp: bool = False                  # deepseek multi-token prediction head
    mtp_loss_weight: float = 0.3

    ssm: SSMConfig | None = None

    frontend: Literal["tokens", "audio_tokens", "vision_patches"] = "tokens"
    dtype: str = "bfloat16"

    # --- derived -----------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.hd

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid state-space decode)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        h, L = self.d_model, self.num_layers
        emb = self.vocab_size * h
        head = 0 if self.tie_embeddings else self.vocab_size * h
        per_layer = 0
        if self.ssm is not None and self.family in ("ssm", "hybrid"):
            d_in = self.ssm.expand * h
            nheads = d_in // self.ssm.head_dim
            if self.ssm.slstm_every:  # xlstm
                pf = self.ssm.proj_factor
                d_up = int(pf * h)
                mlstm = h * d_up * 2 + 3 * d_up * d_up // 1 + d_up * h
                slstm = 4 * h * h + 4 * h * h // self.num_heads + 2 * h * int(1.3 * h)
                n_s = L // self.ssm.slstm_every
                return emb + head + (L - n_s) * mlstm + n_s * slstm
            mamba = (
                h * (2 * d_in + 2 * self.ssm.d_state + nheads)  # in_proj
                + d_in * h                                        # out_proj
                + d_in * self.ssm.conv_kernel + 3 * nheads
            )
            attn_every = self.ssm.shared_attn_every or 0
            shared_attn = (2 * h) * h + h * (self.q_dim + 2 * self.kv_dim) + self.q_dim * h \
                + 3 * h * self.d_ff if attn_every else 0
            return emb + head + L * mamba + shared_attn
        # attention archs
        attn = h * (self.q_dim + 2 * self.kv_dim) + self.q_dim * h
        if self.mla is not None:
            m = self.mla
            attn = (
                h * m.q_lora_rank
                + m.q_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                + h * (m.kv_lora_rank + m.qk_rope_head_dim)
                + m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
                + self.num_heads * m.v_head_dim * h
            )
        ff_mult = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
        if self.moe is not None:
            moe_ff = ff_mult * h * self.moe.d_ff_expert
            n_moe = L - self.moe.first_dense_layers
            per_layer = attn + moe_ff * (self.moe.num_experts + self.moe.num_shared) \
                + h * self.moe.num_experts
            dense_layer = attn + ff_mult * h * self.d_ff
            return emb + head + n_moe * per_layer + self.moe.first_dense_layers * dense_layer
        per_layer = attn + ff_mult * h * self.d_ff
        return emb + head + L * per_layer

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        h, L = self.d_model, self.num_layers
        ff_mult = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
        attn = h * (self.q_dim + 2 * self.kv_dim) + self.q_dim * h
        if self.mla is not None:
            m = self.mla
            attn = (
                h * m.q_lora_rank
                + m.q_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                + h * (m.kv_lora_rank + m.qk_rope_head_dim)
                + m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
                + self.num_heads * m.v_head_dim * h
            )
        moe_ff = ff_mult * h * self.moe.d_ff_expert
        n_moe = L - self.moe.first_dense_layers
        per_moe = attn + moe_ff * (self.moe.top_k + self.moe.num_shared) + h * self.moe.num_experts
        per_dense = attn + ff_mult * h * self.d_ff
        emb = self.vocab_size * h
        head = 0 if self.tie_embeddings else self.vocab_size * h
        return emb + head + n_moe * per_moe + self.moe.first_dense_layers * per_dense

    def validate_for_tp(self, d1: int, d2: int) -> list[str]:
        """Divisibility requirements for an ATP (d1, d2) mesh; returns
        human-readable issue list (empty == valid)."""
        issues = []
        n = d1 * d2
        for nm, v in (("d_model", self.d_model), ("vocab", self.vocab_size)):
            if v % n:
                issues.append(f"{nm}={v} not divisible by tp={n}")
        if self.d_ff and self.d_ff % n:
            issues.append(f"d_ff={self.d_ff} not divisible by tp={n}")
        if self.moe and (ff := self.moe.d_ff_expert) % n:
            issues.append(f"expert d_ff={ff} not divisible by tp={n}")
        return issues

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        changes: dict = dict(
            num_layers=min(self.num_layers, 2),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2),
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            head_dim=32,
            max_seq_len=256,
            local_window=16 if self.local_window else 0,
        )
        if self.ssm is not None:
            changes["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=32, chunk=16,
                shared_attn_every=2 if self.ssm.shared_attn_every else 0,
                slstm_every=2 if self.ssm.slstm_every else 0,
            )
            changes["num_layers"] = 4
            changes["num_heads"] = 4 if self.ssm.slstm_every else 4
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=2, d_ff_expert=64,
                first_dense_layers=min(self.moe.first_dense_layers, 1),
            )
            changes["num_layers"] = 2 + (1 if self.moe.first_dense_layers else 0)
        if self.mla is not None:
            changes["mla"] = MLAConfig(
                q_lora_rank=64, kv_lora_rank=32,
                qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32,
            )
        if self.mrope_sections:
            changes["mrope_sections"] = (4, 6, 6)
        if self.local_global_period:
            changes["num_layers"] = 2
        return dataclasses.replace(self, **changes)


# ---------------------------------------------------------------------------
# Segment plan: every architecture is a list of block segments (models.lm
# scans each segment; the strategy stack prices and plans them per kind).
# Lives here — not in models — so the cost model / plan search can derive
# per-segment workloads from a ModelConfig without importing model code.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Segment:
    kind: str
    count: int          # scan length
    inner: int = 1      # blocks per scan step (zamba/xlstm super-blocks)


def segments(cfg: ModelConfig) -> tuple[Segment, ...]:
    if cfg.ssm is not None and cfg.ssm.slstm_every:          # xlstm
        period = cfg.ssm.slstm_every
        assert cfg.num_layers % period == 0
        return (Segment("xlstm", cfg.num_layers // period, period),)
    if cfg.ssm is not None and cfg.ssm.shared_attn_every:    # zamba2
        per = cfg.ssm.shared_attn_every  # 1 shared attn + (per-1) mamba
        n_super = cfg.num_layers // per
        tail = cfg.num_layers - n_super * per
        segs = [Segment("zamba", n_super, per)]
        if tail:
            segs.append(Segment("mamba", tail))
        return tuple(segs)
    if cfg.moe is not None:
        segs = []
        kind = "mla_moe" if cfg.mla is not None else "moe"
        dense_kind = "mla_dense" if cfg.mla is not None else "dense"
        if cfg.moe.first_dense_layers:
            segs.append(Segment(dense_kind, cfg.moe.first_dense_layers))
        segs.append(Segment(kind, cfg.num_layers - cfg.moe.first_dense_layers))
        return tuple(segs)
    return (Segment("dense", cfg.num_layers),)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


LM_SHAPES = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeConfig:
    for s in LM_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def model_flops_per_token(cfg: ModelConfig) -> float:
    """MODEL_FLOPS = 6*N(active)*  — per token, fwd+bwd (roofline §g)."""
    return 6.0 * cfg.active_param_count()


def math_flops_estimate(cfg: ModelConfig, seq: int, batch: int, kind: str) -> float:
    """Analytic useful-FLOPs estimate incl. attention quadratic term."""
    n_act = cfg.active_param_count()
    tokens = seq * batch
    mult = 6.0 if kind == "train" else 2.0
    flops = mult * n_act * tokens
    if not cfg.is_attention_free and cfg.mla is None:
        # QK^T + AV: 2 * 2 * s^2 * hd * heads per example (causal /2)
        att = 2 * 2 * seq * seq * cfg.hd * cfg.num_heads * batch / 2
        flops += att * (3 if kind == "train" else 1)
    return flops
