"""Assigned architecture config (see assignment sheet for source)."""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, SSMConfig  # noqa: F401

CONFIG = ModelConfig(
    name="qwen3-8b", family="dense",
    num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=12288, vocab_size=151936, head_dim=128, qk_norm=True,
    rope_theta=1000000.0,
)

QWEN3_8B = CONFIG
