"""Assigned architecture config (see assignment sheet for source)."""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, SSMConfig  # noqa: F401

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    d_ff=6144, vocab_size=2048, mlp_kind="gelu", norm_kind="layernorm",
    use_rope=False, frontend="audio_tokens",
)

MUSICGEN_MEDIUM = CONFIG
