"""Assigned architecture config (see assignment sheet for source)."""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, SSMConfig  # noqa: F401

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=10752, vocab_size=100352, rope_theta=500000.0,
    norm_kind="layernorm",
    moe=MoEConfig(num_experts=16, top_k=4, d_ff_expert=10752),
)

DBRX_132B = CONFIG
