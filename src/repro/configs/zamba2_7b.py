"""Assigned architecture config (see assignment sheet for source)."""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, SSMConfig  # noqa: F401

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000,
    ssm=SSMConfig(d_state=64, expand=2, head_dim=64, conv_kernel=4,
                  chunk=64, shared_attn_every=6),
)

ZAMBA2_7B = CONFIG
